"""Paging layer: paged KV, Leap-prefetched streams, expert paging.

Includes the async issue/wait data-path contract (DESIGN.md §4): issued at
step t + consumed at t+1 = prefetched hit, consumed while still in flight =
partial hit, zero-length ring pins bit-equivalent to the sync path, and the
issued-prefetch decomposition always sums.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pool import pool_init, pool_issue, pool_stats, pool_wait, ring_init
from repro.paging import (ExpertPrefetcher, PageAllocator, append_kv,
                          init_paged_kv, linear_page_table,
                          paged_decode_attention)
from repro.paging.prefetch_serving import (PrefetchedStream, multi_stream_consume,
                                           stream_consume, stream_init,
                                           stream_stats, stream_stats_at)


class TestPagedKV:
    def test_append_then_attend_matches_dense(self):
        from repro.models.attention import decode_attention
        B, Hkv, Hq, dh, ps, npps = 2, 2, 4, 16, 4, 4
        pool = init_paged_kv(1, B * npps, ps, Hkv, dh, jnp.float32)
        pt = linear_page_table(B, npps)
        T = ps * npps
        kd = jax.random.normal(jax.random.PRNGKey(0), (B, T, Hkv, dh))
        vd = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh))
        n_tok = 11
        for pos in range(n_tok):
            pool = append_kv(pool, jnp.int32(0), kd[:, pos], vd[:, pos],
                             pt, jnp.int32(pos))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, Hq, dh))
        a = paged_decode_attention(q, pool, jnp.int32(0), pt,
                                   jnp.full((B,), n_tok))
        b = decode_attention(q, kd[:, :], vd[:, :], n_tok)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_allocator_alloc_free(self):
        al = PageAllocator(16)
        p1 = al.alloc_seq(1, 4)
        p2 = al.alloc_seq(2, 4)
        assert len(set(p1) & set(p2)) == 0 and al.in_use == 8
        al.free_seq(1)
        assert al.in_use == 4
        al.alloc_seq(3, 12)
        with pytest.raises(MemoryError):
            al.alloc_seq(4, 1)

    def test_allocator_random_ops_round_trip(self):
        """Property-style alloc/extend/free round trip: no page is ever
        owned twice, ``in_use`` tracks exactly the outstanding pages, and
        exhaustion raises without corrupting state."""
        import random
        rng = random.Random(0)
        n_pages = 24
        al = PageAllocator(n_pages)
        owned: dict[int, list[int]] = {}
        for step in range(300):
            op = rng.random()
            if op < 0.45:
                seq, n = rng.randrange(8), rng.randrange(1, 4)
                if n <= n_pages - sum(map(len, owned.values())):
                    pages = (al.extend_seq(seq, n) if seq in owned
                             else al.alloc_seq(seq, n))
                    assert len(pages) == n
                    owned.setdefault(seq, []).extend(pages)
                else:
                    with pytest.raises(MemoryError):
                        al.alloc_seq(seq, n)
            elif op < 0.8 and owned:
                seq = rng.choice(list(owned))
                assert al.free_seq(seq) == len(owned.pop(seq))
            else:
                assert al.free_seq(999) == 0        # unknown seq is a no-op
            flat = [p for ps in owned.values() for p in ps]
            assert len(flat) == len(set(flat))      # no double allocation
            assert all(0 <= p < n_pages for p in flat)
            assert al.in_use == len(flat)
        for seq in list(owned):
            al.free_seq(seq)
        assert al.in_use == 0
        assert sorted(al.alloc_seq(0, n_pages)) == list(range(n_pages))

    def test_linear_page_table_strided_is_permutation(self):
        """Regression (kv_cache stride bug): ``j*stride % npps`` must be a
        within-sequence permutation — the old precedence bug collided
        physical pages whenever gcd(stride, npps) != 1."""
        for npps, stride in ((8, 3), (8, 5), (9, 2), (7, 6), (8, 1)):
            pt = np.asarray(linear_page_table(3, npps, stride))
            for b in range(3):
                assert sorted(pt[b]) == list(range(b * npps, (b + 1) * npps))
        with pytest.raises(ValueError, match="coprime"):
            linear_page_table(2, 4, 2)              # 0,2,0,2 collision
        with pytest.raises(ValueError, match="coprime"):
            linear_page_table(1, 6, 9)


class TestPrefetchedStream:
    GEOM = PrefetchedStream(n_pages=128, n_slots=24, page_elems=4)

    def _pool(self):
        return jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4)

    def test_sequential_converges_to_prefetch_hits(self):
        sched = jnp.arange(100, dtype=jnp.int32)
        st, sums, info = stream_consume(self._pool(), sched, self.GEOM)
        assert float(info["pref_hit"][20:].mean()) > 0.95
        assert stream_stats(st)["pollution"] == 0

    def test_data_always_correct(self):
        for sched in (jnp.arange(100, dtype=jnp.int32),
                      jax.random.randint(jax.random.PRNGKey(0), (100,), 0, 128),
                      jnp.arange(0, 300, 3, dtype=jnp.int32) % 128):
            st, sums, _ = stream_consume(self._pool(), sched, self.GEOM)
            expect = self._pool()[sched].sum(-1)
            np.testing.assert_allclose(np.asarray(sums), np.asarray(expect))

    def test_random_throttles(self):
        sched = jax.random.randint(jax.random.PRNGKey(1), (150,), 0, 128)
        st, _, _ = stream_consume(self._pool(), sched, self.GEOM)
        assert stream_stats(st)["prefetch_issued"] < 15

    def test_structured_kv_payload_moves_leaves_together(self):
        """DESIGN.md §6: a {"k","v"} payload pytree rides the same stream —
        both leaves of a page move together and the checksum sums them."""
        kv = {"k": jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4),
              "v": -jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4)}
        sched = jnp.arange(60, dtype=jnp.int32)
        for async_dp in (False, True):
            st, sums, info = stream_consume(kv, sched, self.GEOM,
                                            async_datapath=async_dp)
            expect = (kv["k"][sched] + kv["v"][sched]).sum(-1)
            np.testing.assert_allclose(np.asarray(sums), np.asarray(expect))
            assert st["hot"]["k"].shape == (self.GEOM.n_slots, 4)
        assert float(info["pref_hit"][20:].mean()) > 0.9

    def test_multi_stream_isolation(self):
        """Paper Fig. 13: concurrent streams keep their own detectors."""
        scheds = jnp.stack([jnp.arange(80, dtype=jnp.int32),
                            (jnp.arange(80, dtype=jnp.int32) * 3) % 128])
        (st, sums, info) = multi_stream_consume(self._pool(), scheds, self.GEOM)
        assert float(info["pref_hit"][0, 20:].mean()) > 0.9
        assert float(info["pref_hit"][1, 20:].mean()) > 0.9


def _assert_decomposition(s: dict) -> None:
    """Every issued prefetch lands in exactly one bucket (DESIGN.md §4)."""
    assert s["prefetch_issued"] == (s["prefetch_hits"] + s["pollution"]
                                    + s["inflight_at_end"]
                                    + s["resident_unused"]), s
    assert 0 <= s["partial_hits"] <= s["prefetch_hits"]


class TestAsyncDatapath:
    GEOM = PrefetchedStream(n_pages=128, n_slots=24, page_elems=4)

    def _pool(self):
        return jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4)

    def _issue_one(self, page, now=0, delay=1):
        st, ring = pool_init(64, 8), ring_init(4)
        pool = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
        st, ring = pool_issue(st, ring, jnp.asarray([page], jnp.int32),
                              jnp.asarray([True]), jnp.int32(now),
                              jnp.int32(delay))
        return st, ring, pool

    def test_issued_at_t_consumed_at_t1_is_prefetched_hit(self):
        st, ring, pool = self._issue_one(5, now=0, delay=1)
        hot = jnp.zeros((8, 4))
        st, ring, hot, slot, data, info = pool_wait(
            st, ring, hot, pool, jnp.int32(5), jnp.int32(1))
        assert bool(info["prefetched_hit"]) and not bool(info["partial_hit"])
        assert (data == pool[5]).all()
        s = pool_stats(st, ring)
        assert s["prefetch_hits"] == 1 and s["partial_hits"] == 0
        assert s["latency_hidden_frac"] == 1.0

    def test_consumed_at_t_while_in_flight_is_partial_hit(self):
        st, ring, pool = self._issue_one(5, now=0, delay=1)
        hot = jnp.zeros((8, 4))
        st, ring, hot, slot, data, info = pool_wait(
            st, ring, hot, pool, jnp.int32(5), jnp.int32(0))
        assert bool(info["partial_hit"]) and not bool(info["prefetched_hit"])
        assert (data == pool[5]).all()          # residual completed early
        s = pool_stats(st, ring)
        assert s["partial_hits"] == 1 and s["prefetch_hits"] == 1
        assert s["latency_hidden_frac"] == 0.0 and s["inflight_at_end"] == 0

    def test_full_ring_drops_not_issues(self):
        st, ring = pool_init(64, 8), ring_init(2)
        st, ring = pool_issue(st, ring, jnp.arange(4, dtype=jnp.int32),
                              jnp.ones((4,), bool), jnp.int32(0), jnp.int32(1))
        s = pool_stats(st, ring)
        assert s["prefetch_issued"] == 2 and s["ring_drops"] == 2
        assert s["inflight_at_end"] == 2

    def test_data_always_correct_async(self):
        for sched in (jnp.arange(100, dtype=jnp.int32),
                      jax.random.randint(jax.random.PRNGKey(0), (100,), 0, 128),
                      jnp.arange(0, 300, 3, dtype=jnp.int32) % 128):
            st, sums, _ = stream_consume(self._pool(), sched, self.GEOM,
                                         async_datapath=True)
            expect = self._pool()[sched].sum(-1)
            np.testing.assert_allclose(np.asarray(sums), np.asarray(expect))
            _assert_decomposition(stream_stats(st))

    def test_sequential_hides_latency(self):
        sched = jnp.arange(100, dtype=jnp.int32)
        st, _, info = stream_consume(self._pool(), sched, self.GEOM,
                                     async_datapath=True)
        s = stream_stats(st)
        assert float(info["pref_hit"][20:].mean()) > 0.95
        assert s["latency_hidden_frac"] == 1.0 and s["pollution"] == 0

    def test_longer_arrival_delay_yields_partial_hits(self):
        geom = dataclasses.replace(self.GEOM, arrival_delay=2)
        sched = jnp.arange(100, dtype=jnp.int32)
        st, _, info = stream_consume(self._pool(), sched, geom,
                                     async_datapath=True)
        s = stream_stats(st)
        assert s["partial_hits"] > 0 and s["latency_hidden_frac"] < 1.0
        # partials still serve the consumer: coverage stays high
        assert s["coverage"] > 0.9
        _assert_decomposition(s)

    def test_zero_arrival_delay_never_counts_deferred(self):
        """Regression: deferred must stay budget-only — issue runs after the
        step's wait, so a delay-0 deadline is clamped to the next step
        instead of miscounting every landing as budget-deferred."""
        geom = dataclasses.replace(self.GEOM, arrival_delay=0)
        sched = jnp.arange(40, dtype=jnp.int32)
        st, _, info = stream_consume(self._pool(), sched, geom,
                                     async_datapath=True)
        assert int(np.asarray(info["deferred"]).sum()) == 0
        assert stream_stats(st)["deferred"] == 0
        # behavior otherwise matches delay=1 (landing cannot be earlier)
        st1, _, info1 = stream_consume(self._pool(), sched, self.GEOM,
                                       async_datapath=True)
        np.testing.assert_array_equal(np.asarray(info["pref_hit"]),
                                      np.asarray(info1["pref_hit"]))

    def test_zero_ring_bit_equivalent_to_sync(self):
        geom = dataclasses.replace(self.GEOM, ring_size=0)
        for sched in (jnp.arange(80, dtype=jnp.int32),
                      jax.random.randint(jax.random.PRNGKey(1), (80,), 0, 128)):
            st_a, sums_a, info_a = stream_consume(self._pool(), sched, geom,
                                                  async_datapath=True)
            st_s, sums_s, info_s = stream_consume(self._pool(), sched, geom,
                                                  async_datapath=False)
            np.testing.assert_array_equal(np.asarray(sums_a), np.asarray(sums_s))
            for k in ("hit", "pref_hit", "partial_hit"):
                np.testing.assert_array_equal(np.asarray(info_a[k]),
                                              np.asarray(info_s[k]), err_msg=k)
            for k, v in st_s["pool_meta"].items():
                np.testing.assert_array_equal(np.asarray(st_a["pool_meta"][k]),
                                              np.asarray(v), err_msg=k)

    def test_sync_decomposition_sums_too(self):
        for sched in (jnp.arange(100, dtype=jnp.int32),
                      jax.random.randint(jax.random.PRNGKey(2), (100,), 0, 128)):
            st, _, _ = stream_consume(self._pool(), sched, self.GEOM)
            s = stream_stats(st)
            assert s["partial_hits"] == 0 and s["inflight_at_end"] == 0
            _assert_decomposition(s)

    def test_multi_stream_async_isolation(self):
        scheds = jnp.stack([jnp.arange(80, dtype=jnp.int32),
                            (jnp.arange(80, dtype=jnp.int32) * 3) % 128])
        st, sums, info = multi_stream_consume(self._pool(), scheds, self.GEOM,
                                              async_datapath=True)
        assert float(info["pref_hit"][0, 20:].mean()) > 0.9
        assert float(info["pref_hit"][1, 20:].mean()) > 0.9
        expect = self._pool()[scheds].sum(-1)
        np.testing.assert_allclose(np.asarray(sums), np.asarray(expect))

    def test_more_ring_slack_never_loses_hits(self):
        """Deterministic slice of the hypothesis property (see
        tests/test_async_datapath.py): with eviction pressure off, growing
        the in-flight ring can only land a superset of prefetches."""
        pool = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
        for mult in (1, 3, 5):
            sched = (jnp.arange(120, dtype=jnp.int32) * mult) % 64
            prev_hits = prev_pref = -1
            for ring in (1, 2, 4, 8, 16):
                geom = PrefetchedStream(n_pages=64, n_slots=64, page_elems=4,
                                        ring_size=ring)
                st, _, _ = stream_consume(pool, sched, geom,
                                          async_datapath=True)
                s = stream_stats(st)
                assert s["hits"] >= prev_hits
                assert s["prefetch_hits"] >= prev_pref
                prev_hits, prev_pref = s["hits"], s["prefetch_hits"]
                _assert_decomposition(s)


class TestExpertPaging:
    def test_skewed_routing_gets_hits_random_throttles(self):
        ep = ExpertPrefetcher(n_experts=16, n_hot=6, block_elems=8)
        weights = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
        st = ep.init()
        cyc = jnp.asarray(np.tile(np.arange(4), 40), jnp.int32)  # cyclic route
        st, info = ep.consume_route_trace(st, weights, cyc)
        from repro.core.pool import pool_stats
        hits_cyc = pool_stats(st["pool_meta"])["prefetch_hits"]
        st2 = ep.init()
        rnd = jax.random.randint(jax.random.PRNGKey(0), (160,), 0, 16)
        st2, _ = ep.consume_route_trace(st2, weights, rnd)
        issued_rnd = pool_stats(st2["pool_meta"])["prefetch_issued"]
        assert hits_cyc > 50           # cyclic stride +1 detected
        assert issued_rnd < 30         # randomness -> throttled

    def test_async_expert_stream_matches_hits(self):
        ep = ExpertPrefetcher(n_experts=16, n_hot=6, block_elems=8,
                              async_datapath=True)
        weights = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
        st = ep.init()
        cyc = jnp.asarray(np.tile(np.arange(4), 40), jnp.int32)
        st, info = ep.consume_route_trace(st, weights, cyc)
        s = stream_stats(st)
        assert s["prefetch_hits"] > 50
        _assert_decomposition(s)

    def test_budgeted_expert_streams_share_the_link(self):
        """Two routed streams under a 1-block/step link: blocks still land
        correctly, surplus speculation defers instead of blocking routing."""
        ep = ExpertPrefetcher(n_experts=16, n_hot=16, block_elems=8,
                              async_datapath=True, link_budget=1)
        weights = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
        ids = jnp.stack([jnp.asarray(np.tile(np.arange(4), 30), jnp.int32),
                         jnp.asarray(np.tile(np.arange(8), 15), jnp.int32)])
        st, sums, info = ep.consume_route_traces(weights, ids)
        np.testing.assert_allclose(np.asarray(sums),
                                   np.asarray(weights[ids].sum(-1)))
        assert int(np.asarray(info["deferred"]).sum()) > 0
        for i in range(2):
            _assert_decomposition(stream_stats_at(st, i))
