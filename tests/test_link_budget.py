"""Shared-link budget arbitration for the jitted multi-stream path (§5).

Pins the three contracts of DESIGN.md §5:

* ``link_budget=None`` is the legacy isolated path — bit-equivalent to an
  explicit ``vmap(stream_consume)`` — and a large-enough finite budget run
  through the budgeted ``lax.scan`` is bit-equivalent to that same path
  (modulo the ring's ``seq`` bookkeeping stamps, which the unbudgeted path
  never assigns).
* Under a finite budget, per-stream hit / partial / deferral counts agree
  exactly with the lock-step width-B fabric reference
  (``repro.fabric.run_linkstep``) on the same schedules — the quantitative
  bridge between the jitted path and the fabric subsystem.
* The issued-prefetch decomposition still balances once ``deferred`` /
  dropped exist, and demand-first starvation behaves monotonically.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fabric.linkstep import run_linkstep
from repro.obs import (TraceRecorder, assert_traces_equal,
                       decode_stream_events)
from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume,
                                           stream_consume, stream_stats_at)

N_PAGES = 128
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)
GEOM = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                        ring_size=8)
INF = 1 << 20


def _scheds(T: int = 60) -> jnp.ndarray:
    rng = np.random.default_rng(3)
    return jnp.asarray(np.stack([
        np.arange(T) % N_PAGES,
        (np.arange(T) * 3 + 7) % N_PAGES,
        (np.arange(T) * 2 + 50) % N_PAGES,
        rng.integers(0, N_PAGES, T),
    ]), jnp.int32)


def _per_stream(st, i: int) -> dict:
    return stream_stats_at(st, i)


class TestBudgetEquivalence:
    def test_none_budget_is_the_vmap_path(self):
        """link_budget=None must be bit-equivalent to vmap(stream_consume)."""
        scheds = _scheds()
        st_m, sums_m, info_m = multi_stream_consume(POOL, scheds, GEOM,
                                                    async_datapath=True,
                                                    link_budget=None)
        st_v, sums_v, info_v = jax.vmap(
            lambda s: stream_consume(POOL, s, GEOM, async_datapath=True)
        )(scheds)
        np.testing.assert_array_equal(np.asarray(sums_m), np.asarray(sums_v))
        for k in info_v:
            np.testing.assert_array_equal(np.asarray(info_m[k]),
                                          np.asarray(info_v[k]), err_msg=k)
        for part in ("pool_meta", "ring", "leap"):
            for k, v in st_v[part].items():
                np.testing.assert_array_equal(np.asarray(st_m[part][k]),
                                              np.asarray(v), err_msg=k)
        np.testing.assert_array_equal(np.asarray(st_m["hot"]),
                                      np.asarray(st_v["hot"]))

    @pytest.mark.parametrize("async_dp", [False, True])
    def test_infinite_budget_bit_equivalent_to_vmap(self, async_dp):
        """The budgeted scan with budget=inf replays the vmap path exactly."""
        scheds = _scheds()
        st_v, sums_v, info_v = multi_stream_consume(POOL, scheds, GEOM,
                                                    async_datapath=async_dp)
        st_b, sums_b, info_b = multi_stream_consume(POOL, scheds, GEOM,
                                                    async_datapath=async_dp,
                                                    link_budget=INF)
        np.testing.assert_array_equal(np.asarray(sums_v), np.asarray(sums_b))
        for k in info_v:
            np.testing.assert_array_equal(np.asarray(info_v[k]),
                                          np.asarray(info_b[k]), err_msg=k)
        for k, v in st_v["pool_meta"].items():
            np.testing.assert_array_equal(np.asarray(st_b["pool_meta"][k]),
                                          np.asarray(v), err_msg=k)
        for k, v in st_v["ring"].items():
            if k == "seq":       # only the arbiter assigns issue-order stamps
                continue
            np.testing.assert_array_equal(np.asarray(st_b["ring"][k]),
                                          np.asarray(v), err_msg=k)
        assert int(info_b["link_deferred"].sum()) == 0

    def test_budgeted_data_always_correct(self):
        scheds = _scheds()
        for budget in (1, 2, 5):
            st, sums, _ = multi_stream_consume(POOL, scheds, GEOM,
                                               async_datapath=True,
                                               link_budget=budget)
            expect = POOL[scheds].sum(-1)
            np.testing.assert_allclose(np.asarray(sums), np.asarray(expect))


class TestFabricCrossValidation:
    """Jitted counts == lock-step width-B fabric reference, per stream."""

    @pytest.mark.parametrize("budget", [None, 1, 2, 3, 6, 64])
    def test_counts_match_linkstep(self, budget):
        scheds = _scheds(80)
        st, _, info = multi_stream_consume(
            POOL, scheds, GEOM, async_datapath=True,
            link_budget=INF if budget is None else budget)
        rec = TraceRecorder()
        rep = run_linkstep(np.asarray(scheds), N_PAGES, budget,
                           ring_size=GEOM.ring_size,
                           arrival_delay=GEOM.arrival_delay,
                           pw_max=GEOM.pw_max, h_size=GEOM.h_size,
                           n_split=GEOM.n_split, recorder=rec)
        for i in range(scheds.shape[0]):
            j = _per_stream(st, i)
            r = rep.stream_summary(i)
            if {k: j[k] for k in r} != r:
                # §8: localize the first divergent event before failing on
                # end-of-run totals — names the exact (step, stream, page).
                assert_traces_equal(
                    decode_stream_events(scheds, info, n_pages=N_PAGES),
                    rec.events, context=f"stream {i}, budget {budget}")
            assert {k: j[k] for k in r} == r, f"stream {i}, budget {budget}"

    def test_crossval_with_longer_arrival_delay(self):
        import dataclasses
        geom = dataclasses.replace(GEOM, arrival_delay=2, ring_size=6)
        scheds = _scheds(50)
        for budget in (2, 4):
            st, _, _ = multi_stream_consume(POOL, scheds, geom,
                                            async_datapath=True,
                                            link_budget=budget)
            rep = run_linkstep(np.asarray(scheds), N_PAGES, budget,
                               ring_size=6, arrival_delay=2,
                               pw_max=geom.pw_max, h_size=geom.h_size,
                               n_split=geom.n_split)
            for i in range(scheds.shape[0]):
                j = _per_stream(st, i)
                r = rep.stream_summary(i)
                assert {k: j[k] for k in r} == r, f"stream {i}, budget {budget}"


class TestBudgetSemantics:
    def test_decomposition_balances_with_deferred_and_drops(self):
        """deferred annotates buckets; it never breaks the §4.3 sum."""
        scheds = _scheds(70)
        for budget in (0, 1, 3, 8):
            st, _, info = multi_stream_consume(POOL, scheds, GEOM,
                                               async_datapath=True,
                                               link_budget=budget)
            for i in range(scheds.shape[0]):
                s = _per_stream(st, i)
                assert s["prefetch_issued"] == (
                    s["prefetch_hits"] + s["pollution"]
                    + s["inflight_at_end"] + s["resident_unused"]), s
                assert 0 <= s["partial_hits"] <= s["prefetch_hits"]
                # every deferral is a completed (landed or partial) or
                # still-pending prefetch; it can never exceed what was issued
                assert 0 <= s["deferred"] <= s["prefetch_issued"]

    def test_zero_budget_starves_prefetch_demand_still_served(self):
        """B=0: nothing ever lands — every covered access is a partial."""
        scheds = _scheds(60)
        st, sums, info = multi_stream_consume(POOL, scheds, GEOM,
                                              async_datapath=True,
                                              link_budget=0)
        np.testing.assert_allclose(np.asarray(sums),
                                   np.asarray(POOL[scheds].sum(-1)))
        for i in range(scheds.shape[0]):
            s = _per_stream(st, i)
            assert s["prefetch_hits"] == s["partial_hits"]
            assert s["resident_unused"] == 0 and s["pollution"] == 0

    def test_tighter_budget_never_creates_hits(self):
        """Landing capacity only ever helps: hits are monotone in budget."""
        scheds = _scheds(70)
        prev = None
        for budget in (0, 1, 2, 4, 8, INF):
            st, _, _ = multi_stream_consume(POOL, scheds, GEOM,
                                            async_datapath=True,
                                            link_budget=budget)
            full_hits = sum(_per_stream(st, i)["hits"]
                            - _per_stream(st, i)["partial_hits"]
                            for i in range(scheds.shape[0]))
            if prev is not None:
                assert full_hits >= prev, budget
            prev = full_hits

    def test_deferred_zero_when_budget_covers_offered_load(self):
        scheds = _scheds(60)
        S = scheds.shape[0]
        budget = S * (1 + GEOM.pw_max)        # demand + every candidate
        st, _, info = multi_stream_consume(POOL, scheds, GEOM,
                                           async_datapath=True,
                                           link_budget=budget)
        assert int(info["link_deferred"].sum()) == 0
        assert all(_per_stream(st, i)["deferred"] == 0 for i in range(S))
