"""Measurement tooling: loop-aware jaxpr FLOP counter + HLO analysis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.flop_count import count_fn
from repro.launch.hlo_analysis import (analyze_hlo, loop_structure,
                                       split_computations)


class TestFlopCount:
    def test_plain_matmul(self):
        M = 64
        st = count_fn(lambda a, b: a @ b,
                      jax.ShapeDtypeStruct((M, M), jnp.float32),
                      jax.ShapeDtypeStruct((M, M), jnp.float32))
        assert st["dot_flops"] == 2 * M ** 3

    def test_scan_scales_by_length(self):
        M, L = 32, 7

        def f(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return y

        st = count_fn(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                      jax.ShapeDtypeStruct((L, M, M), jnp.float32))
        assert st["dot_flops"] == L * 2 * M ** 3

    def test_nested_scan(self):
        M, L1, L2 = 16, 3, 5

        def inner(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return y

        def outer(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (inner(c, w), None), x, ws)
            return y

        st = count_fn(outer, jax.ShapeDtypeStruct((M, M), jnp.float32),
                      jax.ShapeDtypeStruct((L1, L2, M, M), jnp.float32))
        assert st["dot_flops"] == L1 * L2 * 2 * M ** 3

    def test_remat_counts_once_forward(self):
        M = 32

        @jax.checkpoint
        def f(a, b):
            return a @ b

        st = count_fn(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                      jax.ShapeDtypeStruct((M, M), jnp.float32))
        assert st["dot_flops"] == 2 * M ** 3

    def test_model_train_step_close_to_analytic(self):
        """smoke config: counted dot flops within 35% of 8·N·D (remat)."""
        from repro.configs import get_smoke_config
        from repro.models.model import build_model
        cfg = get_smoke_config("qwen2_5_3b")
        model = build_model(cfg)
        pshapes = jax.eval_shape(lambda k: model.init_params(k)[0],
                                 jax.random.PRNGKey(0))
        B, S = 2, 32
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        st = count_fn(lambda p, b: jax.value_and_grad(model.train_forward)(
            p, b)[0], pshapes, batch)
        n, _ = cfg.param_count()
        analytic = 8 * n * B * S          # fwd+bwd+remat ≈ 8·N·D
        assert 0.4 * analytic < st["dot_flops"] < 2.5 * analytic


HLO_SAMPLE = """
HloModule test

%body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %ag = f32[8,8]{1,0} all-gather(%x), channel_id=1
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ag)
}

%cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main.1 (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  %ar = f32[8,8]{1,0} all-reduce(%y), channel_id=2
  ROOT %r = f32[8,8]{1,0} add(%q, %z)
}
"""


class TestHloAnalysis:
    def test_split_and_loops(self):
        comps = split_computations(HLO_SAMPLE)
        assert {"body.1", "cond.1", "main.1"} <= set(comps)
        counts = loop_structure(comps)
        assert counts["body.1"] == 5

    def test_collectives_loop_scaled(self):
        res = analyze_hlo(HLO_SAMPLE)
        # in-loop all-gather x5, entry all-reduce x1
        assert res["collectives"]["all-gather"]["count"] == 5
        assert res["collectives"]["all-gather"]["bytes"] == 5 * 8 * 8 * 4
        assert res["collectives"]["all-reduce"]["count"] == 1

    def test_converts_skipped(self):
        hlo = HLO_SAMPLE.replace(
            "%ar = f32[8,8]{1,0} all-reduce(%y), channel_id=2",
            "%cv = f32[8,8]{1,0} convert(%y)")
        res = analyze_hlo(hlo)
        assert "all-reduce" not in res["collectives"]


class TestSelectiveScanKernel:
    @pytest.mark.parametrize("B,S,di,N,bt,bd", [
        (1, 16, 8, 4, 4, 4), (2, 32, 16, 8, 8, 8), (1, 24, 8, 16, 8, 8),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, B, S, di, N, bt, bd, dtype):
        from repro.kernels import selective_scan
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di))).astype(dtype)
        b = jax.random.normal(ks[1], (B, S, N), dtype)
        c = jax.random.normal(ks[2], (B, S, N), dtype)
        x = jax.random.normal(ks[3], (B, S, di), dtype)
        a = -jnp.exp(jax.random.normal(ks[4], (di, N))).astype(dtype)
        y1 = selective_scan(dt, b, c, x, a, block_t=bt, block_d=bd,
                            interpret=True)
        y2 = selective_scan(dt, b, c, x, a, use_kernel=False)
        tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32),
                                   atol=tol, rtol=tol)


class TestMambaKernelPath:
    def test_sscan_kernel_flag_matches_scan_path(self, monkeypatch):
        import os
        from repro.models.mamba import apply_mamba, mamba_init
        p, _ = mamba_init(jax.random.PRNGKey(0), 16, 2, 8, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
        y1, st1 = apply_mamba(p, x, 8, True)
        monkeypatch.setenv("REPRO_OPT", "sscan_kernel")
        y2, st2 = apply_mamba(p, x, 8, True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(st1["h"]), np.asarray(st2["h"]),
                                   atol=1e-4)
