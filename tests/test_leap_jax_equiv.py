"""Bit-exactness: jittable Leap controller == NumPy reference (paper Alg. 1+2)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.leap_jax import leap_init, leap_step, leap_step_batched
from repro.core.prefetcher import LeapPrefetcher


def _drive_both(pages, h_size=32, n_split=8, pw_max=8):
    ref = LeapPrefetcher(h_size=h_size, n_split=n_split, pw_max=pw_max)
    st_ = leap_init(h_size)
    out_ref, out_jax = [], []
    outstanding_r, outstanding_j = set(), set()
    for p in pages:
        hit_r = p in outstanding_r
        outstanding_r.discard(p)
        c_r = ref.on_fault(p, hit_r)
        outstanding_r.update(c_r)
        out_ref.append(c_r)

        hit_j = p in outstanding_j
        outstanding_j.discard(p)
        st_, cands, valid = leap_step(st_, jnp.int32(p), jnp.asarray(hit_j),
                                      n_split=n_split, pw_max=pw_max)
        c_j = [int(c) for c, v in zip(cands, valid) if v]
        outstanding_j.update(c_j)
        out_jax.append(c_j)
    return out_ref, out_jax


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 1 << 16), min_size=5, max_size=200))
def test_bit_exact_on_random_traces(pages):
    r, j = _drive_both(pages)
    assert r == j


def test_bit_exact_on_structured_trace():
    pages = (list(range(100, 160)) + [7, 900, 13]
             + list(range(5000, 4000, -25)) + [3] * 5)
    r, j = _drive_both(pages)
    assert r == j


def test_batched_streams_are_isolated():
    """vmap'ed controller: each stream's decisions independent (§4.1)."""
    B, T = 4, 64
    st_ = leap_init(batch=(B,))
    seqs = np.stack([np.arange(T) * (i + 1) + 1000 * i for i in range(B)])
    hits = jnp.zeros((B,), bool)
    for t in range(T):
        st_, cands, valid = leap_step_batched(st_, jnp.int32(seqs[:, t]), hits)
    # after convergence every stream prefetches along its own stride
    for i in range(B):
        got = [int(c) for c, v in zip(cands[i], valid[i]) if v]
        assert got and got[0] - int(seqs[i, -1]) == i + 1
