"""Chaos fabric: the fault-injection harness that gates DESIGN.md §9.

The load-bearing pins:

* **Count equivalence under faults** — the jitted chaos path
  (``sharded_multi_stream_consume(..., chaos=spec)``) produces *exactly*
  the per-stream counters of the lock-step twin (``run_shardstep``) for
  every fault axis — stragglers, NIC degradation, node loss with page
  re-homing, elastic grants — alone and combined, across placements,
  budgets and shard counts, with static and adaptive deadlines.
* **Zero trace divergence** — the decoded jitted event log and the twin's
  recorded trace agree event for event under the all-axes spec (the §8
  differ finds no divergence), including the node-death eviction sweep.
* **Linkstep reduction** — at one shard the chaos tables reduce to
  per-step ``budget`` / ``arrival_delay`` sequences for ``run_linkstep``,
  and the three mirrors agree.
* **Deadline adaptation** — under a straggler window, static deadlines
  defer essentially every landing; the integer EWMA estimator converges
  to the dilated delay and pulls deferrals back to a bounded warmup
  transient (the regression the adaptive path must never lose).
* **Seeded random-spec property** — a seeded loop over random specs,
  shard counts, placements and budgets keeps the mirrors glued where
  hand-picked cases can't reach.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.fabric import ChaosSpec, FabricScenario, TenantSpec, run_fabric
from repro.fabric.chaos import (EST_A, EST_D, EST_ONE, INF, compile_chaos,
                                est_init, est_step, rehome_shard)
from repro.fabric.linkstep import run_linkstep
from repro.fabric.shardstep import run_shardstep
from repro.obs import TraceRecorder, assert_traces_equal, decode_stream_events
from repro.paging.kv_cache import PageAllocator
from repro.paging.prefetch_serving import PrefetchedStream, stream_stats_at
from repro.paging.sharded_pool import (ShardedPoolCfg,
                                       sharded_multi_stream_consume)

pytestmark = pytest.mark.chaos

N_PAGES = 64
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)
GEOM = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                        ring_size=8)

#: every counter ``stream_summary`` reports — the full equivalence surface
KEYS = ("faults", "hits", "misses", "prefetch_issued", "prefetch_hits",
        "partial_hits", "deferred", "pollution", "resident_unused",
        "inflight_at_end", "ring_drops")

SPECS = {
    "slowdown": ChaosSpec(slowdown=((0, 3, 5, 25), (1, 2, 10, 30))),
    "degradation": ChaosSpec(degradation=((0, 1, 8, 30),)),
    "node_loss": ChaosSpec(node_loss=(1, 15)),
    "grants": ChaosSpec(grants=((0, 3, 5, 30), (2, 1, 10, 20))),
    "all_adaptive": ChaosSpec(slowdown=((0, 3, 5, 25), (1, 2, 10, 30)),
                              degradation=((0, 1, 8, 30),),
                              node_loss=(1, 15),
                              grants=((0, 3, 5, 30), (2, 1, 10, 20)),
                              adaptive_deadline=True),
}


def _scheds(T=40, S=3, seed=7):
    rng = np.random.default_rng(seed)
    rows = [np.arange(T) % N_PAGES,
            (np.arange(T) * 3 + 11) % N_PAGES,
            rng.integers(0, N_PAGES, T)]
    while len(rows) < S:
        rows.append(rng.integers(0, N_PAGES, T))
    return np.stack(rows[:S]).astype(np.int32)


def _both(scheds, fab: ShardedPoolCfg, spec, recorder=None):
    """Run the jitted chaos path and the lock-step twin on one config."""
    st, _, info = sharded_multi_stream_consume(
        POOL, jnp.asarray(scheds), GEOM, fab, chaos=spec)
    rep = run_shardstep(scheds, N_PAGES, fab.n_shards, fab.placement,
                        fab.link_budget, ring_size=GEOM.ring_size,
                        near_delay=fab.near_delay, far_delay=fab.far_delay,
                        pw_max=GEOM.pw_max, h_size=GEOM.h_size,
                        n_split=GEOM.n_split, recorder=recorder, chaos=spec)
    return st, info, rep


def _assert_counts(st, rep, S, ctx):
    for i in range(S):
        j = stream_stats_at(st, i)
        r = rep.stream_summary(i)
        for k in KEYS:
            assert j[k] == r[k], (f"{ctx}: stream {i} {k}: "
                                  f"jitted {j[k]} != twin {r[k]}")


class TestCountEquivalence:
    """Jitted chaos scan == lock-step twin, counter for counter."""

    @pytest.mark.parametrize("name", sorted(SPECS))
    def test_each_axis_interleave(self, name):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=2, placement="interleave",
                             link_budget=2, near_delay=1, far_delay=2)
        st, _, rep = _both(scheds, fab, SPECS[name])
        _assert_counts(st, rep, len(scheds), name)

    def test_all_axes_block_four_shards(self):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=4, placement="block",
                             link_budget=1, near_delay=1, far_delay=3)
        spec = ChaosSpec(slowdown=((2, 2, 6, 28),), node_loss=(3, 12),
                         adaptive_deadline=True)
        st, _, rep = _both(scheds, fab, spec)
        _assert_counts(st, rep, len(scheds), "block/4")

    def test_empty_spec_matches_clean_path(self):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=2, placement="interleave",
                             link_budget=2, near_delay=1, far_delay=2)
        st_chaos, _, _ = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, chaos=ChaosSpec())
        st_clean, _, _ = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab)
        for i in range(len(scheds)):
            assert stream_stats_at(st_chaos, i) == stream_stats_at(st_clean, i)


class TestTracePin:
    """Decoded jitted events == twin's recorded trace under all four axes."""

    def test_all_axes_zero_divergence(self):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=2, placement="interleave",
                             link_budget=2, near_delay=1, far_delay=2)
        rec = TraceRecorder()
        st, info, _ = _both(scheds, fab, SPECS["all_adaptive"], recorder=rec)
        stats = [stream_stats_at(st, i) for i in range(len(scheds))]
        jit_events = decode_stream_events(scheds, info, n_pages=N_PAGES,
                                          final_stats=stats, n_shards=2,
                                          placement="interleave")
        assert_traces_equal(jit_events, rec.events, context="chaos all-axes")


class TestLinkstepReduction:
    """At one shard the chaos tables are linkstep's per-step sequences."""

    def test_one_shard_three_mirrors(self):
        scheds = _scheds()
        T, S = scheds.shape[1], scheds.shape[0]
        spec = ChaosSpec(slowdown=((0, 3, 5, 25),),
                         degradation=((0, 1, 8, 30),))
        fab = ShardedPoolCfg(n_shards=1, placement="interleave",
                             link_budget=2, near_delay=1, far_delay=2)
        rec_shard = TraceRecorder()
        st, info, rep_shard = _both(scheds, fab, spec, recorder=rec_shard)
        cz = compile_chaos(spec, n_steps=T, n_streams=S, n_shards=1,
                           n_pages=N_PAGES, placement="interleave",
                           base_budget=2)
        budget_seq = [None if int(b) >= INF else int(b)
                      for b in cz["budget"][:, 0]]
        delay_seq = [int(d) for d in cz["dilation"][:, 0]]
        rec_link = TraceRecorder()
        rep_link = run_linkstep(scheds, N_PAGES, budget_seq,
                                ring_size=GEOM.ring_size,
                                arrival_delay=delay_seq, nominal_delay=1,
                                pw_max=GEOM.pw_max, h_size=GEOM.h_size,
                                n_split=GEOM.n_split, recorder=rec_link)
        for i in range(S):
            assert rep_link.stream_summary(i) == rep_shard.stream_summary(i)
        _assert_counts(st, rep_link, S, "linkstep")
        stats = [stream_stats_at(st, i) for i in range(S)]
        jit_events = decode_stream_events(scheds, info, n_pages=N_PAGES,
                                          final_stats=stats)
        assert_traces_equal(jit_events, rec_link.events, context="linkstep")


class TestRandomSpecs:
    """Seeded property: random specs keep the mirrors glued."""

    def test_random_specs_count_equivalence(self):
        rng = np.random.default_rng(20260808)
        for trial in range(6):
            G = int(rng.choice([1, 2, 4]))
            placement = str(rng.choice(["interleave", "block"]))
            budget = [None, 1, 2, 3][rng.integers(0, 4)]
            T = int(rng.integers(20, 45))
            S = int(rng.integers(2, 4))

            def window():
                a = int(rng.integers(0, T - 1))
                return a, int(rng.integers(a + 1, T + 5))

            slow = []
            for _ in range(rng.integers(0, 3)):
                o, r = window()
                slow.append((int(rng.integers(0, G)),
                             int(rng.integers(2, 5)), o, r))
            degr = []
            for _ in range(rng.integers(0, 2)):
                o, r = window()
                degr.append((int(rng.integers(0, G)),
                             int(rng.integers(0, 3)), o, r))
            grants = []
            for _ in range(rng.integers(0, 2)):
                o, r = window()
                grants.append((int(rng.integers(0, S)),
                               int(rng.integers(1, 6)), o, r))
            loss = None
            if G >= 2 and rng.random() < 0.5:
                loss = (int(rng.integers(0, G)), int(rng.integers(5, T)))
            spec = ChaosSpec(slowdown=tuple(slow), degradation=tuple(degr),
                             grants=tuple(grants), node_loss=loss,
                             adaptive_deadline=bool(rng.random() < 0.5))
            scheds = _scheds(T=T, S=S, seed=int(rng.integers(0, 1 << 31)))
            fab = ShardedPoolCfg(n_shards=G, placement=placement,
                                 link_budget=budget, near_delay=1,
                                 far_delay=2)
            st, _, rep = _both(scheds, fab, spec)
            _assert_counts(st, rep, S, f"trial {trial}: {spec}")


class TestDeadlineAdaptation:
    """The regression: static collapses under a straggler, adaptive holds."""

    T, ONSET = 120, 24

    def _run(self, adaptive: bool):
        # all-strided streams: every stream sustains a trend, so every
        # (stream, shard) estimator cell gets landing observations
        scheds = np.stack([(np.arange(self.T) * 3 + 7 * s) % N_PAGES
                           for s in range(3)]).astype(np.int32)
        spec = ChaosSpec(slowdown=tuple((g, 2, self.ONSET, self.T)
                                        for g in range(2)),
                         adaptive_deadline=adaptive)
        fab = ShardedPoolCfg(n_shards=2, placement="interleave",
                             link_budget=None, near_delay=1, far_delay=1)
        rec = TraceRecorder()
        st, info, rep = _both(scheds, fab, spec, recorder=rec)
        return st, info, rep, rec

    def test_static_defers_every_landing_in_window(self):
        _, _, rep, _ = self._run(adaptive=False)
        landings = sum(rep.landed[self.ONSET:])
        deferred = sum(s.deferred for s in rep.per_stream)
        assert landings > 50          # the scenario actually lands pages
        assert deferred >= 0.9 * landings

    def test_adaptive_converges_within_warmup(self):
        _, info, rep, rec = self._run(adaptive=True)
        _, _, rep_static, _ = self._run(adaptive=False)
        deferred = sum(s.deferred for s in rep.per_stream)
        static_deferred = sum(s.deferred for s in rep_static.per_stream)
        # deferrals collapse to a bounded warmup transient...
        assert deferred <= 0.15 * static_deferred
        # ...and no deferral fires once the EWMA has had time to converge
        last_defer = max((e.step for e in rec.events if e.kind == "defer"),
                         default=-1)
        assert last_defer <= self.ONSET + 30
        # the estimator tracked the dilated truth (delay 1 -> 2 steps)
        est = np.asarray(info["est_q"], dtype=np.float64) / EST_ONE
        assert np.all(np.abs(est - 2.0) < 0.25)


class TestEstimator:
    """Integer Q8 EWMA: bit-identical across int domains, sane dynamics."""

    def test_jnp_and_python_bit_identical(self):
        rng = np.random.default_rng(11)
        est = int(est_init(1, 1, 1, 2)[0, 0])
        est_j = jnp.asarray(est, jnp.int32)
        for _ in range(200):
            obs_n = int(rng.integers(1, 5))
            obs_sum = int(rng.integers(obs_n, obs_n * 12))
            est = est_step(est, obs_sum, obs_n)
            est_j = est_step(est_j, jnp.int32(obs_sum), jnp.int32(obs_n))
            assert est == int(est_j)

    def test_converges_to_constant_observation(self):
        est = EST_ONE                      # prior: 1 step
        for _ in range(40):
            est = est_step(est, 6, 1)      # observe 6 steps, forever
        assert abs(est - 6 * EST_ONE) <= EST_D

    def test_est_init_uses_stream_home(self):
        e = est_init(4, 2, 1, 3)
        assert e.shape == (4, 2) and e.dtype == np.int32
        assert e[0, 0] == EST_ONE and e[0, 1] == 3 * EST_ONE
        assert e[1, 1] == EST_ONE and e[1, 0] == 3 * EST_ONE
        assert EST_A == 1 and EST_D == 4   # alpha pinned with the mirrors


class TestSpecAndTables:
    """ChaosSpec validation, JSON round-trip, compile_chaos invariants."""

    def test_json_round_trip(self):
        spec = SPECS["all_adaptive"]
        assert ChaosSpec.from_json(spec.to_json()) == spec
        assert ChaosSpec.from_json(ChaosSpec().to_json()) == ChaosSpec()

    def test_any_faults(self):
        assert not ChaosSpec().any_faults
        assert not ChaosSpec(adaptive_deadline=True).any_faults
        for name, spec in SPECS.items():
            assert spec.any_faults, name

    @pytest.mark.parametrize("bad", [
        dict(slowdown=((0, 0, 5, 10),)),          # factor < 1
        dict(slowdown=((0, 2, 10, 10),)),         # empty window
        dict(degradation=((0, -1, 5, 10),)),      # negative budget
        dict(grants=((0, -2, 5, 10),)),           # negative grant
        dict(node_loss=(1, 2, 3)),                # not (shard, step)
        dict(slowdown=((0, 2, 5),)),              # not a 4-tuple
    ])
    def test_invalid_specs_rejected(self, bad):
        with pytest.raises(ValueError):
            ChaosSpec(**bad)

    def test_compile_rejects_out_of_range(self):
        kw = dict(n_steps=10, n_streams=2, n_shards=2, n_pages=16,
                  placement="interleave", base_budget=None)
        with pytest.raises(ValueError):
            compile_chaos(ChaosSpec(slowdown=((2, 2, 0, 5),)), **kw)
        with pytest.raises(ValueError):
            compile_chaos(ChaosSpec(grants=((5, 2, 0, 5),)), **kw)
        with pytest.raises(ValueError):
            compile_chaos(ChaosSpec(node_loss=(0, 3)),
                          **{**kw, "n_shards": 1})

    def test_tables_shapes_and_windows(self):
        spec = ChaosSpec(slowdown=((1, 3, 2, 6),), degradation=((0, 1, 4, 8),),
                         grants=((1, 2, 0, 4),), node_loss=(1, 5))
        cz = compile_chaos(spec, n_steps=10, n_streams=2, n_shards=2,
                           n_pages=16, placement="interleave", base_budget=4)
        assert cz["dilation"].shape == (10, 2)
        assert list(cz["dilation"][:, 1]) == [1, 1, 3, 3, 3, 3, 1, 1, 1, 1]
        assert list(cz["budget"][:, 0]) == [4, 4, 4, 4, 1, 1, 1, 1, 4, 4]
        assert list(cz["grant"][:, 1])[:4] == [2, 2, 2, 2]
        assert int(cz["grant"][5, 1]) == INF
        assert cz["t_fail"] == 5
        # interleave: odd pages homed on shard 1 die and re-home to shard 0
        assert list(cz["dead_pages"]) == list(range(1, 16, 2))
        assert np.all(cz["home"][1][cz["dead_pages"]] == 0)
        assert np.all(cz["home"][0] == np.arange(16) % 2)

    def test_rehome_is_deterministic_and_avoids_dead(self):
        for G in (2, 3, 4):
            for dead in range(G):
                for p in range(32):
                    h = rehome_shard(p, dead, dead, G)
                    assert 0 <= h < G and h != dead
                    assert h == rehome_shard(p, dead, dead, G)
                # a surviving page never moves
                alive = (dead + 1) % G
                assert rehome_shard(5, alive, dead, G) == alive


class TestPageAllocatorRecycle:
    def test_recycle_round_trip(self):
        al = PageAllocator(8)
        a = al.alloc_seq(1, 3)
        b = al.alloc_seq(2, 3)
        assert al.in_use == 6
        # yank one page from each owner + one already-free page
        n = al.recycle([a[1], b[0], 7])
        assert n == 2
        assert al.in_use == 4
        assert al.owned[1] == [a[0], a[2]]
        assert al.owned[2] == b[1:]
        # reclaimed pages are allocatable again
        c = al.alloc_seq(3, 4)
        assert set(c) & {a[1], b[0]}
        # freeing an owner whose pages were recycled is still consistent
        al.free_seq(1)
        al.free_seq(2)
        al.free_seq(3)
        assert al.in_use == 0 and sorted(al.free) == list(range(8))

    def test_recycle_whole_owner_removes_entry(self):
        al = PageAllocator(4)
        pages = al.alloc_seq(9, 2)
        assert al.recycle(pages) == 2
        assert 9 not in al.owned
        assert al.free_seq(9) == 0


class TestEngineChaos:
    """Event-engine fault hooks: sanity, not bit-pinned (continuous clock)."""

    def _tenants(self, n=3):
        return [TenantSpec(f"t{i}", [(j * 3 + i * 7) % 64
                                     for j in range(150)], home_node=i % 2)
                for i in range(n)]

    def test_slowdown_stretches_makespan(self):
        base = FabricScenario(tenants=self._tenants(), n_nodes=2, n_pages=64,
                              placement="interleave", seed=1)
        slow = FabricScenario(tenants=self._tenants(), n_nodes=2, n_pages=64,
                              placement="interleave", seed=1,
                              chaos=ChaosSpec(slowdown=((0, 8, 50, 10_000),
                                                        (1, 8, 50, 10_000))))
        r0, r1 = run_fabric(base), run_fabric(slow)
        assert r1.makespan > 1.5 * r0.makespan

    def test_node_loss_completes_and_rehomes(self):
        spec = ChaosSpec(node_loss=(1, 500),
                         degradation=((0, 1, 100, 2000),),
                         grants=((0, 8, 50, 1500),))
        r = run_fabric(FabricScenario(tenants=self._tenants(), n_nodes=2,
                                      n_pages=64, placement="interleave",
                                      seed=1, chaos=spec))
        assert all(t.completion_time > 0 for t in r.tenants)

    def test_node_loss_on_single_node_rejected(self):
        with pytest.raises(ValueError):
            run_fabric(FabricScenario(tenants=self._tenants(1), n_nodes=1,
                                      n_pages=64, placement="interleave",
                                      seed=1,
                                      chaos=ChaosSpec(node_loss=(0, 100))))
