"""Stateless int8 page codec: the backing store of the compressed cold tier.

DESIGN.md §12.3 splits the int8 codecs: the gradient path carries an
error-feedback residual across steps, the page codec must be a *pure
function* of the page bytes (pages are read back many times, out of
order — there is no "next step" to carry a residual into). These tests
pin the purity contract:

* **Reconstruction bound** — every element reconstructs within
  ``scale/2`` (round-to-nearest over ``scale = max|page|/127 + 1e-12``,
  no element clips).
* **Edge pages** — all-zero pages reconstruct exactly; a single outlier
  sets the scale and still reconstructs within the bound (the flat
  remainder pays the outlier's resolution — that is the lossy trade).
* **Payload dtypes** — bf16 and f32 payloads both honor the bound
  against their f32 view; the round trip preserves shape and dtype.
* **Idempotence** — ``page_roundtrip`` is a projection: applying it
  twice is bit-identical to applying it once (demotion re-compressing an
  already-compressed page must not drift). Holds whenever the page
  magnitude is not degenerate (``max|page| >= 1e-4`` keeps the ``1e-12``
  scale epsilon below f32 resolution); the all-zero page is idempotent
  trivially.

The property-based section needs ``hypothesis`` (skipped when absent);
the deterministic slice above it always runs.
"""

from __future__ import annotations

import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.compression import (compress_page, decompress_page,
                                       page_roundtrip)

RNG = np.random.default_rng(0)


def _check_bound(page) -> None:
    """|decompress(compress(page)) - page| <= scale/2, elementwise."""
    q, scale = compress_page(jnp.asarray(page))
    assert q.dtype == jnp.int8
    out = np.asarray(decompress_page(q, scale))
    ref = np.asarray(page, np.float32)
    bound = float(scale) / 2 * (1 + 1e-5)       # f32 rounding headroom
    np.testing.assert_array_less(np.abs(out - ref), bound + 1e-30)


# --------------------------------------------------------------------------
# deterministic slice (always runs)
# --------------------------------------------------------------------------
class TestPageCodecDeterministic:
    def test_error_bound_gaussian_page(self):
        _check_bound(RNG.normal(size=(128,)).astype(np.float32))

    def test_all_zero_page_reconstructs_exactly(self):
        q, scale = compress_page(jnp.zeros((64,), jnp.float32))
        assert int(np.abs(np.asarray(q)).sum()) == 0
        np.testing.assert_array_equal(np.asarray(decompress_page(q, scale)),
                                      np.zeros(64, np.float32))

    def test_single_outlier_sets_scale_and_stays_in_bound(self):
        page = np.full(64, 1e-3, np.float32)
        page[17] = 100.0
        q, scale = compress_page(jnp.asarray(page))
        # the outlier owns the top quantization level; no clipping
        assert int(np.asarray(q)[17]) == 127
        assert abs(float(scale) - 100.0 / 127.0) < 1e-6
        _check_bound(page)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_payload_dtypes(self, dtype):
        page = jnp.asarray(RNG.normal(size=(64,)), dtype)
        _check_bound(page)
        rt = page_roundtrip(page)
        assert rt.shape == page.shape and rt.dtype == page.dtype

    def test_double_compress_idempotent(self):
        page = jnp.asarray(RNG.normal(size=(96,)), jnp.float32)
        once = np.asarray(page_roundtrip(page))
        twice = np.asarray(page_roundtrip(jnp.asarray(once)))
        np.testing.assert_array_equal(once, twice)

    def test_stateless_no_history_dependence(self):
        """Same bytes -> same (q, scale), whatever was compressed before
        (the gradient codec would fail this: its residual carries over)."""
        a = jnp.asarray(RNG.normal(size=(32,)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(32,)), jnp.float32)
        q1, s1 = compress_page(a)
        compress_page(b)                          # interleaved other page
        q2, s2 = compress_page(a)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        assert float(s1) == float(s2)

    def test_batched_roundtrip_matches_per_page(self):
        """vmap(page_roundtrip) over a victim batch == page-at-a-time —
        the serving engine demotes victims as one batched roundtrip."""
        pages = jnp.asarray(RNG.normal(size=(8, 16)), jnp.float32)
        batched = np.asarray(jax.vmap(page_roundtrip)(pages))
        single = np.stack([np.asarray(page_roundtrip(pages[i]))
                           for i in range(8)])
        np.testing.assert_array_equal(batched, single)


# --------------------------------------------------------------------------
# property-based slice (needs hypothesis)
# --------------------------------------------------------------------------
if importlib.util.find_spec("hypothesis") is not None:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    _payload = hnp.arrays(
        np.float32, st.integers(min_value=1, max_value=64),
        elements=st.floats(min_value=-1e4, max_value=1e4, width=32))

    class TestPageCodecProperties:
        @settings(deadline=None, max_examples=50)
        @given(_payload)
        def test_reconstruction_bound(self, page):
            _check_bound(page)

        @settings(deadline=None, max_examples=50)
        @given(_payload)
        def test_roundtrip_idempotent(self, page):
            if 0.0 < np.max(np.abs(page)) < 1e-4:
                page = page * (1e-4 / np.max(np.abs(page)))  # off-degenerate
            once = np.asarray(page_roundtrip(jnp.asarray(page)))
            twice = np.asarray(page_roundtrip(jnp.asarray(once)))
            np.testing.assert_array_equal(once, twice)

        @settings(deadline=None, max_examples=25)
        @given(_payload)
        def test_bf16_payload_bound(self, page):
            _check_bound(jnp.asarray(page, jnp.bfloat16))
else:                                             # pragma: no cover
    def test_property_slice_needs_hypothesis():
        pytest.importorskip("hypothesis")
