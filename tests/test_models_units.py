"""Model-layer unit tests: attention paths, rotary, MoE, chunked CE, scans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (blocked_attention, decode_attention,
                                    full_attention)
from repro.models.layers import (apply_rotary, chunked_ce_loss, mrope_angles,
                                 rope_angles)
from repro.models.moe import apply_moe, apply_moe_dense_ref, moe_init


class TestAttention:
    def _qkv(self, B=2, Sq=32, Sk=32, Hq=4, Hkv=2, dh=16, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        return (jax.random.normal(ks[0], (B, Sq, Hq, dh)),
                jax.random.normal(ks[1], (B, Sk, Hkv, dh)),
                jax.random.normal(ks[2], (B, Sk, Hkv, dh)))

    @pytest.mark.parametrize("window", [0, 8])
    @pytest.mark.parametrize("bq,bk", [(8, 8), (16, 4), (32, 32)])
    def test_blocked_equals_full(self, window, bq, bk):
        q, k, v = self._qkv()
        a = full_attention(q, k, v, causal=True, window=window)
        b = blocked_attention(q, k, v, causal=True, window=window,
                              block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_blocked_gradients_finite(self):
        q, k, v = self._qkv()

        def loss(q):
            return blocked_attention(q, k, v, block_q=8, block_k=8).sum()

        g = jax.grad(loss)(q)
        assert bool(jnp.isfinite(g).all())

    def test_decode_matches_last_row(self):
        q, k, v = self._qkv()
        f = full_attention(q, k, v, causal=True)[:, -1:]
        d = decode_attention(q[:, -1:], k, v, length=k.shape[1])
        np.testing.assert_allclose(np.asarray(d), np.asarray(f), atol=2e-5)

    def test_softcap(self):
        q, k, v = self._qkv()
        a = full_attention(q, k, v, softcap=20.0)
        b = blocked_attention(q, k, v, softcap=20.0, block_q=8, block_k=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestRotary:
    def test_mrope_degenerates_to_rope_on_text(self):
        S, dh = 16, 32
        pos = jnp.arange(S)
        p3 = jnp.broadcast_to(pos, (3, 2, S))
        a = rope_angles(pos, dh, 1e4)
        m = mrope_angles(p3, dh, 1e4, (4, 6, 6))
        np.testing.assert_allclose(np.asarray(m[0]), np.asarray(a), atol=1e-6)

    def test_rotary_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
        ang = rope_angles(jnp.arange(8), 32, 1e4)
        y = apply_rotary(x, ang[None, :, None, :])
        np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                                   np.linalg.norm(np.asarray(x), axis=-1),
                                   rtol=1e-5)

    def test_rotary_relative_property(self):
        """<R(p)q, R(p+d)k> depends only on d (per 2-dim pair sumed)."""
        dh = 16
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dh))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, dh))
        def dot_at(p):
            aq = rope_angles(jnp.array([p]), dh, 1e4)
            ak = rope_angles(jnp.array([p + 5]), dh, 1e4)
            qr = apply_rotary(q, aq[None, :, None, :])
            kr = apply_rotary(k, ak[None, :, None, :])
            return float(jnp.sum(qr * kr))
        assert dot_at(0) == pytest.approx(dot_at(37), rel=1e-4)


class TestMoE:
    def test_grouped_matches_dense_ref_when_capacity_ample(self):
        p, _ = moe_init(jax.random.PRNGKey(0), 16, 32, 4, 0, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
        y, aux = apply_moe(p, x, 2, capacity_factor=8.0)
        yr = apply_moe_dense_ref(p, x, 2)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
        assert float(aux) > 0

    def test_capacity_drops_bounded(self):
        """With cf=1.0 some tokens drop; output stays finite & bounded."""
        p, _ = moe_init(jax.random.PRNGKey(0), 16, 32, 8, 0, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
        y, _ = apply_moe(p, x, 1, capacity_factor=1.0)
        yr = apply_moe_dense_ref(p, x, 1)
        assert bool(jnp.isfinite(y).all())
        assert float(jnp.abs(y).max()) <= float(jnp.abs(yr).max()) * 2 + 1

    def test_shared_expert_added(self):
        p, _ = moe_init(jax.random.PRNGKey(0), 16, 32, 4, 1, jnp.float32)
        assert "shared" in p
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
        y, _ = apply_moe(p, x, 1, capacity_factor=4.0)
        assert bool(jnp.isfinite(y).all())


class TestChunkedCE:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 3), st.sampled_from([8, 12, 16]),
           st.sampled_from([1, 4, 8]))
    def test_matches_naive(self, B, S, n_chunks):
        D, V = 8, 11
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        h = jax.random.normal(ks[0], (B, S, D))
        w = jax.random.normal(ks[1], (D, V))
        t = jax.random.randint(ks[2], (B, S), 0, V)
        mask = (jnp.arange(S)[None] < S - 2).astype(jnp.float32) * jnp.ones((B, 1))
        got = chunked_ce_loss(h, w, t, mask, n_chunks)
        logits = (h @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, t[..., None], -1)[..., 0]
        want = ((lse - gold) * mask).sum() / mask.sum()
        assert float(got) == pytest.approx(float(want), rel=1e-5)


class TestRecurrentChunking:
    """Chunked scan == unchunked semantics (mamba/xlstm train paths)."""

    def test_mamba_chunk_invariance(self):
        from repro.models.mamba import apply_mamba, mamba_init, _pick_chunk
        p, _ = mamba_init(jax.random.PRNGKey(0), 16, 2, 8, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
        y1 = apply_mamba(p, x, 8)
        # different chunking via monkeypatched chunk picker
        import repro.models.mamba as M
        orig = M._pick_chunk
        M._pick_chunk = lambda S, target=128: 4
        try:
            y2 = apply_mamba(p, x, 8)
        finally:
            M._pick_chunk = orig
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)

    def test_mlstm_chunk_invariance(self):
        from repro.models.xlstm import apply_mlstm, mlstm_init
        import repro.models.mamba as M
        p, _ = mlstm_init(jax.random.PRNGKey(0), 16, 4, 2.0, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
        y1 = apply_mlstm(p, x, 4, 4)
        orig = M._pick_chunk
        M._pick_chunk = lambda S, target=128: 6
        try:
            y2 = apply_mlstm(p, x, 4, 4)
        finally:
            M._pick_chunk = orig
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
