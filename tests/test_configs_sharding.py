"""Arch configs (published dims, param counts) + sharding rule resolution."""

import jax
import pytest

from repro.configs import (ARCHS, SHAPES, get_config, get_smoke_config,
                           input_specs, skip_reason, supports_long_context)
from repro.distributed.sharding import named_sharding_for, rules_for

PUBLISHED_TOTALS = {            # billions, +-12% tolerance
    "qwen2_vl_72b": 72, "jamba_v01_52b": 52, "llama4_maverick_400b": 400,
    "phi35_moe_42b": 42, "stablelm_12b": 12, "qwen2_72b": 72,
    "qwen2_5_3b": 3.1, "h2o_danube3_4b": 4.0, "seamless_m4t_medium": 1.2,
    "xlstm_350m": 0.35,
}
PUBLISHED_ACTIVE = {"jamba_v01_52b": 12, "llama4_maverick_400b": 17,
                    "phi35_moe_42b": 6.6}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    cfg.validate()
    tot, act = cfg.param_count()
    assert tot / 1e9 == pytest.approx(PUBLISHED_TOTALS[arch], rel=0.30), arch
    if arch in PUBLISHED_ACTIVE:
        assert act / 1e9 == pytest.approx(PUBLISHED_ACTIVE[arch], rel=0.30)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_is_same_family(arch):
    c, s = get_config(arch), get_smoke_config(arch)
    assert c.family == s.family
    assert (c.moe_every > 0) == (s.moe_every > 0)
    assert (c.sliding_window > 0) == (s.sliding_window > 0)
    assert c.rope_type == s.rope_type


def test_40_cells_have_specs_or_reasons():
    n_ok = n_skip = 0
    for a in ARCHS:
        for s in SHAPES:
            sp = input_specs(a, s, smoke=True)
            if sp["skip"]:
                n_skip += 1
            else:
                n_ok += 1
                assert "batch" in sp
    assert n_ok + n_skip == 40
    assert n_skip == 7          # 7 pure full-attention archs skip long_500k


def test_long_context_support_flags():
    assert supports_long_context(get_config("jamba_v01_52b"))
    assert supports_long_context(get_config("xlstm_350m"))
    assert supports_long_context(get_config("h2o_danube3_4b"))   # SWA
    assert not supports_long_context(get_config("qwen2_72b"))
    assert skip_reason(get_config("qwen2_72b"), "long_500k") is not None


class TestShardingResolution:
    """Pure-logic tests on a 1-device mesh (axis sizes 1 exercise shape
    handling; divisibility/duplication logic is tested via a fake mesh)."""

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}

    def _parts(self, axes, shape, rules):
        """Run the resolution logic with the fake mesh; return PartitionSpec
        entries (NamedSharding construction is bypassed via monkeypatch)."""
        import repro.distributed.sharding as S
        captured = {}

        class NS:
            def __init__(self, mesh, spec):
                captured["spec"] = spec

        orig = S.NamedSharding
        S.NamedSharding = NS
        try:
            named_sharding_for(axes, shape, self.FakeMesh(), rules)
        finally:
            S.NamedSharding = orig
        return tuple(captured["spec"])

    def test_basic_tp_fsdp(self):
        rules = rules_for("train", False)
        parts = self._parts(("embed", "ff"), (8192, 29568), rules)
        assert parts == ("data", "model")

    def test_divisibility_fallback(self):
        rules = rules_for("train", False)
        parts = self._parts(("embed", "vocab"), (1024, 256206), rules)
        assert parts == ("data", None)        # 256206 % 16 != 0

    def test_duplicate_axis_dropped(self):
        rules = rules_for("train", False)
        parts = self._parts(("experts", "ff"), (128, 6400), rules)
        assert parts == ("model", None)       # ff would reuse 'model'

    def test_batch_of_one_replicates(self):
        rules = rules_for("serve", False)
        parts = self._parts(("layers", "batch", "kv_seq"), (4, 1, 524288),
                            rules)
        assert parts == (None, None, "model")

    def test_multipod_batch_axes(self):
        rules = rules_for("train", True)
        parts = self._parts(("batch",), (256,), rules)
        assert parts == (("pod", "data"),)
