"""Observability (DESIGN.md §8): decoders, trace diff, registry, export.

The load-bearing pins:

* **Decode contract** — expanding the jitted info arrays into page-lifecycle
  events and folding them back (``events_to_counts``) reproduces
  ``pool_stats`` exactly, on both data planes; the §4.3 decomposition
  ``issued == prefetch_hits + pollution + inflight_at_end + resident_unused``
  holds at *event* granularity (hypothesis-driven over random schedules,
  ring sizes, arrival delays and link budgets).
* **Trace equivalence** — the decoded jitted trace and the lock-step twin's
  recorded trace have no divergent event (``first_divergence is None``),
  for both the single-link and the sharded fabric.
* **Divergence localization** — plant a single corrupted event in an
  otherwise-identical trace and the differ names its exact
  ``(step, stream, kind)`` (and page, when page-level).
"""

import dataclasses
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as hst
except ImportError:                       # deterministic tests still run
    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    hst = _StrategyStub()

from repro.fabric.linkstep import run_linkstep
from repro.fabric.shardstep import run_shardstep
from repro.obs import (Event, Registry, TraceRecorder, assert_traces_equal,
                       decode_stream_events, decode_sweep_events,
                       events_to_counts, first_divergence, percentile_ladder,
                       read_jsonl, summary_events, to_chrome_trace,
                       write_chrome_trace, write_jsonl)
from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume,
                                           stream_stats_at)

N_PAGES = 64
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)
GEOM = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                        ring_size=8)
INF = 1 << 20

#: counters both ``pool_stats`` and ``events_to_counts`` report.
PINNED = ("hits", "misses", "partial_hits", "prefetch_hits",
          "prefetch_issued", "deferred", "ring_drops", "pollution")


def _scheds(T=40, S=3, seed=7):
    rng = np.random.default_rng(seed)
    rows = [np.arange(T) % N_PAGES,
            (np.arange(T) * 3 + 11) % N_PAGES,
            rng.integers(0, N_PAGES, T)]
    return jnp.asarray(np.stack(rows[:S]), jnp.int32)


def _run(scheds, budget, geom=GEOM):
    return multi_stream_consume(POOL, scheds, geom, async_datapath=True,
                                link_budget=INF if budget is None else budget)


def _decode(scheds, st, info, geom=GEOM, **kw):
    stats = [stream_stats_at(st, i) for i in range(scheds.shape[0])]
    return decode_stream_events(scheds, info, n_pages=geom.n_pages,
                                final_stats=stats, **kw), stats


class TestEventSchema:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown event kind"):
            Event("teleport", 0, 0)

    def test_events_to_counts_by_hand(self):
        ev = [Event("hit", 0, 0), Event("hit", 1, 0, pref=True),
              Event("partial", 2, 0, pref=True), Event("miss", 3, 0),
              Event("issue", 0, 0, count=4), Event("land", 1, 0, count=2),
              Event("drop", -1, 0, count=3), Event("evict", -1, 0)]
        c = events_to_counts(ev, 1)[0]
        assert c["hits"] == 3                     # partial counts as a hit
        assert c["prefetch_hits"] == 2 and c["partial_hits"] == 1
        assert c["misses"] == 1 and c["prefetch_issued"] == 4
        assert c["landed"] == 2 and c["ring_drops"] == 3
        assert c["pollution"] == 1


class TestRegistry:
    def test_counters_and_histograms(self):
        reg = Registry()
        reg.counter("faults").add(3)
        reg.counter("faults").add(2)
        reg.histogram("lat").extend([1.0, 2.0, 3.0])
        s = reg.summary()
        assert s["counters"]["faults"] == 5
        assert s["histograms"]["lat"]["n"] == 3
        assert s["histograms"]["lat"]["max"] == 3.0

    def test_span_blocks_on_device_result(self):
        reg = Registry()
        with reg.span("work") as sp:
            sp.sync = jnp.arange(8).sum()        # forces block_until_ready
        assert reg.histogram("work").samples[0] > 0.0

    def test_empty_ladder_is_nan(self):
        lad = percentile_ladder([])
        assert lad["n"] == 0 and math.isnan(lad["p50"])


class TestDecodePinsCounters:
    """events_to_counts(decode(info)) == pool_stats, both data planes."""

    @pytest.mark.parametrize("budget", [None, 1, 3])
    def test_stream_decode_matches_pool_stats(self, budget):
        scheds = _scheds()
        st, _, info = _run(scheds, budget)
        events, stats = _decode(scheds, st, info)
        counts = events_to_counts(events, scheds.shape[0])
        for i, ps in enumerate(stats):
            assert {k: counts[i][k] for k in PINNED} == \
                {k: ps[k] for k in PINNED}, f"stream {i}, budget {budget}"

    @pytest.mark.parametrize("budget", [2, INF])
    def test_decomposition_at_event_granularity(self, budget):
        """§4.3 identity walked over *events*, not end counters."""
        scheds = _scheds(T=50)
        st, _, info = _run(scheds, budget)
        events, stats = _decode(scheds, st, info)
        for i, ps in enumerate(stats):
            mine = [e for e in events if e.stream == i]
            issued = sum(e.count for e in mine if e.kind == "issue")
            pref_hits = sum(e.count for e in mine
                            if e.kind in ("hit", "partial") and e.pref)
            evicted = sum(e.count for e in mine if e.kind == "evict")
            assert issued == (pref_hits + evicted + ps["inflight_at_end"]
                              + ps["resident_unused"]), f"stream {i}"
            landed = sum(e.count for e in mine if e.kind == "land")
            partials = sum(e.count for e in mine if e.kind == "partial")
            assert issued == landed + partials + ps["inflight_at_end"]

    def test_sweep_decode_matches_tiered_stats(self):
        from repro.paging.kv_cache import linear_page_table
        from repro.paging.tiered_kv import (TieredKV, tiered_init,
                                            tiered_min_slots, tiered_stats,
                                            tiered_sweep)
        B, npps, ps = 4, 8, 4
        geom = TieredKV(B * npps, 1, ps, 2, 8, chunk=2, pw_max=4,
                        ring_size=8, use_kernel=False)
        geom = dataclasses.replace(
            geom, n_slots=tiered_min_slots(npps, geom))
        k = jnp.arange(B * npps * ps * 2 * 8,
                       dtype=jnp.float32).reshape(B * npps, ps, 2, 8)
        cold = {"k": k, "v": k + 1.0}
        pt = linear_page_table(B, npps)
        st = tiered_init(geom, B, jnp.float32)
        events = []
        n_chunks = -(-npps // geom.chunk)
        for sweep in range(2):
            st, info = tiered_sweep(st, cold, pt, geom, async_datapath=True)
            events.extend(decode_sweep_events(
                info, step_offset=sweep * n_chunks))
        stats = [tiered_stats(st, i) for i in range(B)]
        events.extend(summary_events(stats))
        counts = events_to_counts(events, B)
        for i, ps_ in enumerate(stats):
            assert {k: counts[i][k] for k in PINNED} == \
                {k: ps_[k] for k in PINNED}, f"stream {i}"


class TestTraceEquivalence:
    """Decoded jitted trace == lock-step twin's recorded trace."""

    @pytest.mark.parametrize("budget", [1, 3])
    def test_linkstep_twin_has_no_divergence(self, budget):
        scheds = _scheds(T=60)
        st, _, info = _run(scheds, budget)
        jit_events, _ = _decode(scheds, st, info)
        rec = TraceRecorder()
        run_linkstep(np.asarray(scheds), N_PAGES, budget,
                     ring_size=GEOM.ring_size,
                     arrival_delay=GEOM.arrival_delay, pw_max=GEOM.pw_max,
                     h_size=GEOM.h_size, n_split=GEOM.n_split, recorder=rec)
        assert_traces_equal(jit_events, rec.events,
                            context=f"budget={budget}")

    def test_shardstep_twin_has_no_divergence(self):
        from repro.paging.sharded_pool import (ShardedPoolCfg,
                                               sharded_multi_stream_consume)
        scheds = _scheds(T=50)
        fab = ShardedPoolCfg(n_shards=2, placement="interleave",
                             link_budget=2, near_delay=1, far_delay=2)
        st, _, info = sharded_multi_stream_consume(POOL, scheds, GEOM, fab)
        jit_events, _ = _decode(scheds, st, info, n_shards=2,
                                placement="interleave")
        rec = TraceRecorder()
        run_shardstep(np.asarray(scheds), N_PAGES, 2, "interleave", 2,
                      ring_size=GEOM.ring_size, near_delay=1, far_delay=2,
                      pw_max=GEOM.pw_max, h_size=GEOM.h_size,
                      n_split=GEOM.n_split, recorder=rec)
        assert_traces_equal(jit_events, rec.events, context="sharded")


def _twin_trace(budget=2):
    scheds = _scheds(T=60)
    rec = TraceRecorder()
    run_linkstep(np.asarray(scheds), N_PAGES, budget,
                 ring_size=GEOM.ring_size, arrival_delay=GEOM.arrival_delay,
                 pw_max=GEOM.pw_max, h_size=GEOM.h_size,
                 n_split=GEOM.n_split, recorder=rec)
    return rec.events


class TestPlantedDivergence:
    """A single corrupted event must be named by exact coordinates."""

    def test_flipped_page_is_localized(self):
        a = _twin_trace(budget=6)        # ample budget: full hits + lands
        idx, victim = next((i, e) for i, e in enumerate(a)
                           if e.kind == "hit" and e.step > 5)
        b = list(a)
        b[idx] = dataclasses.replace(victim, page=(victim.page + 1) % N_PAGES)
        d = first_divergence(a, b)
        assert d is not None
        assert (d.step, d.stream, d.kind) == (victim.step, victim.stream,
                                              "hit")
        assert d.pages is not None       # page-level: names the exact page
        only_a, only_b = d.pages
        assert any(p == victim.page for p, _ in only_a)
        with pytest.raises(AssertionError, match=f"step {victim.step}"):
            assert_traces_equal(a, b)

    def test_dropped_land_event_is_localized(self):
        a = _twin_trace(budget=6)
        idx, victim = next((i, e) for i, e in enumerate(a)
                           if e.kind == "land" and e.step > 5)
        b = a[:idx] + a[idx + 1:]
        d = first_divergence(a, b)
        assert d is not None
        assert (d.step, d.stream, d.kind) == (victim.step, victim.stream,
                                              "land")
        assert d.count_a == d.count_b + 1

    def test_identical_traces_have_no_divergence(self):
        a = _twin_trace()
        assert first_divergence(a, list(a)) is None


class TestFabricEngineRecorder:
    def test_event_engine_trace_matches_tenant_report(self):
        """The continuous-time engine's recorded events reproduce the
        per-tenant report counters (hits incl. partials; §8)."""
        from repro.fabric.sim import FabricScenario, run_fabric
        from repro.fabric.tenants import TenantSpec
        specs = [TenantSpec(f"t{i}", (np.arange(200) * (i + 1)) % 64,
                            cache_capacity=32) for i in range(2)]
        rec = TraceRecorder()
        report = run_fabric(FabricScenario(specs, seed=1), recorder=rec)
        counts = events_to_counts(rec.events, 2)
        for i, ten in enumerate(report.tenants):
            assert counts[i]["hits"] == ten.cache_hits, f"tenant {i}"
            assert counts[i]["misses"] == ten.misses, f"tenant {i}"
            assert counts[i]["hits"] + counts[i]["misses"] == ten.faults
        assert any(e.kind == "issue" for e in rec.events)
        assert any(e.kind == "land" for e in rec.events)


class TestExport:
    def _events(self):
        scheds = _scheds(T=20)
        st, _, info = _run(scheds, 2)
        events, _ = _decode(scheds, st, info)
        return events

    def test_chrome_trace_structure(self):
        events = self._events()
        doc = to_chrome_trace(events, counters={"link": [1, 2, 3]})
        assert "traceEvents" in doc
        phases = {e["ph"] for e in doc["traceEvents"]}
        assert phases <= {"M", "X", "C", "i"}
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert xs and all("ts" in e and "dur" in e for e in xs)
        assert any(e["ph"] == "C" and e["name"] == "link"
                   for e in doc["traceEvents"])

    def test_chrome_trace_file_is_json(self, tmp_path):
        p = str(tmp_path / "trace.json")
        write_chrome_trace(p, self._events())
        with open(p) as f:
            doc = json.load(f)
        assert doc["traceEvents"]

    def test_jsonl_roundtrip(self, tmp_path):
        events = self._events()
        p = str(tmp_path / "trace.jsonl")
        write_jsonl(p, events)
        assert read_jsonl(p) == events


# -- hypothesis: the decode contract over random geometry --------------------
@settings(max_examples=12, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1),
       ring=hst.sampled_from([2, 4, 8]),
       delay=hst.sampled_from([1, 2, 3]),
       budget=hst.sampled_from([0, 1, 2, 4, INF]))
def test_event_log_pins_counters_property(seed, ring, delay, budget):
    """Random schedules/geometry: decoded events reproduce pool_stats and
    the §4.3 decomposition holds at event granularity."""
    geom = dataclasses.replace(GEOM, ring_size=ring, arrival_delay=delay)
    rng = np.random.default_rng(seed)
    scheds = jnp.asarray(rng.integers(0, N_PAGES, (2, 24)), jnp.int32)
    st, _, info = multi_stream_consume(POOL, scheds, geom,
                                       async_datapath=True,
                                       link_budget=budget)
    events, stats = _decode(scheds, st, info, geom=geom)
    counts = events_to_counts(events, 2)
    for i, ps in enumerate(stats):
        assert {k: counts[i][k] for k in PINNED} == \
            {k: ps[k] for k in PINNED}, f"stream {i}"
        mine = [e for e in events if e.stream == i]
        issued = sum(e.count for e in mine if e.kind == "issue")
        pref_hits = sum(e.count for e in mine
                        if e.kind in ("hit", "partial") and e.pref)
        evicted = sum(e.count for e in mine if e.kind == "evict")
        assert issued == (pref_hits + evicted + ps["inflight_at_end"]
                          + ps["resident_unused"])
