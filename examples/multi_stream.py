"""Paper Fig. 13 analogue: concurrent isolated streams over one shared pool.

Part 1 — fabric simulation (``repro.fabric``): two tenants, a
well-behaved sequential stream and a noisy bursty neighbor, contend for
one remote-memory link. The same pair runs through (a) the stock shared
data path — one communal read-ahead detector + LRU cache + shared-FIFO
link — and (b) Leap's isolated path — per-tenant trackers, eager
caches, per-tenant async queue pairs (§4.1/§4.4). The printed per-tenant
tail-latency comparison is the paper's Fig. 13 story: isolation keeps
the neighbor's burst out of the victim's p99.

Part 2 — jax serving twin (``repro.paging``): four request streams with
different access patterns keep their own Leap detector + hot buffer over
a shared disaggregated pool; the random stream throttles itself while
the regular streams converge to prefetched hits.

Part 3 — the same four streams on a *budgeted* shared link
(``link_budget``, DESIGN.md §5): demand fetches are arbitrated first
each step, surplus prefetches arrive late (``deferred``) — coverage
survives because demands complete in-flight prefetches early as partial
hits instead of queueing behind them.

Run: PYTHONPATH=src python examples/multi_stream.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import traces
from repro.fabric import FabricScenario, TenantSpec, run_fabric
from repro.paging.prefetch_serving import PrefetchedStream, multi_stream_consume

# -- part 1: two tenants through the fabric, shared vs isolated --------------
def tenant_specs():
    return [
        TenantSpec("victim_seq", traces.sequential(3000), policy="leap",
                   cache_capacity=64, model="rdma_lean"),
        TenantSpec("noisy_burst", traces.random_pages(3000, seed=5) + (1 << 40),
                   policy="next_n_line", policy_kwargs={"n": 8},
                   cache_capacity=64, eviction="lru", model="rdma_lean",
                   arrival="bursty", burst_len=64, idle_time=100.0),
    ]

shared = run_fabric(FabricScenario(tenant_specs(), data_path="shared",
                                   shared_policy="read_ahead",
                                   shared_model="rdma_block"))
isolated = run_fabric(FabricScenario(tenant_specs(), data_path="isolated",
                                     arbitration="per_tenant_qp"))

print("fabric: shared data path vs per-tenant isolation (µs)")
print(f"{'tenant':14s} {'path':9s} {'p50':>8s} {'p99':>8s} {'p99.9':>8s} "
      f"{'compl_ms':>9s}")
for rep, path in ((shared, "shared"), (isolated, "isolated")):
    for t in rep.tenants:
        print(f"{t.name:14s} {path:9s} {t.latency['p50']:8.1f} "
              f"{t.latency['p99']:8.1f} {t.latency['p99.9']:8.1f} "
              f"{t.completion_time / 1e3:9.1f}")

v_sh, v_iso = shared.tenant("victim_seq"), isolated.tenant("victim_seq")
assert v_iso.latency["p99"] < v_sh.latency["p99"]
assert v_iso.completion_time < v_sh.completion_time
print(f"victim p99: {v_sh.latency['p99']:.1f} -> {v_iso.latency['p99']:.1f} µs "
      f"({v_sh.latency['p99'] / v_iso.latency['p99']:.1f}x better isolated)\n")

# -- part 2: jax serving twin -------------------------------------------------
geom = PrefetchedStream(n_pages=1024, n_slots=32, page_elems=8)
pool = jnp.arange(1024 * 8, dtype=jnp.float32).reshape(1024, 8)

T = 240
rng = np.random.default_rng(0)
schedules = np.stack([
    np.arange(T) % 1024,                          # sequential
    (np.arange(T) * 5) % 1024,                    # stride-5
    np.concatenate([np.arange(T // 2) * 2,        # phase shift
                    8000 - np.arange(T // 2) * 3]) % 1024,
    rng.integers(0, 1024, T),                     # random (throttles)
]).astype(np.int32)

state, sums, info = multi_stream_consume(pool, jnp.asarray(schedules), geom)
names = ["sequential", "stride-5", "phase-shift", "random"]
for i, n in enumerate(names):
    hit = float(info["pref_hit"][i, T // 4:].mean())
    print(f"{n:12s} warm prefetch-hit rate: {hit:.3f}")
hits = [float(info["pref_hit"][i, T // 4:].mean()) for i in range(4)]
assert min(hits[:3]) > 0.85 and hits[3] < 0.2
print("multi_stream OK: isolation beats the shared path, regular streams "
      "converge, random throttles")

# -- part 3: shared link with a per-step fetch budget -------------------------
state_b, _, info_b = multi_stream_consume(pool, jnp.asarray(schedules), geom,
                                          async_datapath=True, link_budget=2)
deferred = int(np.asarray(info_b["link_deferred"]).sum())
partials = int(np.asarray(info_b["partial_hit"]).sum())
covered = float((np.asarray(info_b["pref_hit"])
                 | np.asarray(info_b["partial_hit"]))[:3, T // 4:].mean())
print(f"\nbudgeted link (2 pages/step across 4 streams): "
      f"{deferred} prefetches deferred, {partials} partial hits, "
      f"regular-stream coverage {covered:.3f}")
assert covered > 0.85          # demand-first keeps serving the consumers
