"""Paper Fig. 13 analogue: concurrent isolated streams over one shared pool.

Four request streams with different access patterns (sequential, stride,
phase-shifting, random) run concurrently against a shared disaggregated
pool; each keeps its own Leap detector + hot buffer (the per-process
isolation of paper §4.1). The random stream throttles itself while the
regular streams converge to prefetched hits.

Run: PYTHONPATH=src python examples/multi_stream.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.paging.prefetch_serving import PrefetchedStream, multi_stream_consume

geom = PrefetchedStream(n_pages=1024, n_slots=32, page_elems=8)
pool = jnp.arange(1024 * 8, dtype=jnp.float32).reshape(1024, 8)

T = 240
rng = np.random.default_rng(0)
schedules = np.stack([
    np.arange(T) % 1024,                          # sequential
    (np.arange(T) * 5) % 1024,                    # stride-5
    np.concatenate([np.arange(T // 2) * 2,        # phase shift
                    8000 - np.arange(T // 2) * 3]) % 1024,
    rng.integers(0, 1024, T),                     # random (throttles)
]).astype(np.int32)

state, sums, info = multi_stream_consume(pool, jnp.asarray(schedules), geom)
names = ["sequential", "stride-5", "phase-shift", "random"]
for i, n in enumerate(names):
    hit = float(info["pref_hit"][i, T // 4:].mean())
    print(f"{n:12s} warm prefetch-hit rate: {hit:.3f}")
hits = [float(info["pref_hit"][i, T // 4:].mean()) for i in range(4)]
assert min(hits[:3]) > 0.85 and hits[3] < 0.2
print("multi_stream OK: regular streams converge, random throttles")
