"""Quickstart: the paper's mechanism in 60 lines.

1. NumPy trace simulator — Leap vs Linux read-ahead on a Stride-10 trace
   (paper Fig. 2/7: read-ahead misses everything, Leap converges).
2. The same controller jitted in-model: a page stream served from a hot
   buffer with prefetches fetched one step ahead.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import PageCache, make_prefetcher, simulate, traces
from repro.paging.prefetch_serving import (PrefetchedStream, stream_consume,
                                           stream_stats)

# -- 1. trace-driven simulation (paper's setting) ---------------------------
trace = traces.stride(5000, step=10)

for name, eviction, model in (("read_ahead", "lru", "rdma_block"),
                              ("leap", "eager", "rdma_lean")):
    r = simulate(trace, make_prefetcher(name), PageCache(256, eviction),
                 model=model, think_time=3.0)
    p = r.stats.latency_percentiles()
    print(f"{name:11s} hit={r.stats.hit_rate:5.3f} "
          f"p50={p['p50']:6.2f}us p99={p['p99']:7.2f}us")

# -- 2. jitted in-model stream (TPU-side integration) ------------------------
geom = PrefetchedStream(n_pages=512, n_slots=32, page_elems=16)
pool = jnp.arange(512 * 16, dtype=jnp.float32).reshape(512, 16)
schedule = jnp.asarray(np.arange(300) * 3 % 512, jnp.int32)   # stride-3 sweep
state, sums, info = stream_consume(pool, schedule, geom)
print("jitted stream:", stream_stats(state))
assert float(info["pref_hit"][50:].mean()) > 0.9
print("OK: prefetched hit rate",
      round(float(info["pref_hit"][50:].mean()), 3))
