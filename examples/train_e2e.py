"""End-to-end training example: ~100M-param dense LM, full substrate stack.

Uses the training driver (data pipeline -> sharded-step -> AdamW ->
async checkpoint/restart -> straggler monitor). The default invocation is
CPU-sized; pass --full for the ~100M/300-step run described in DESIGN.md.

Run: PYTHONPATH=src python examples/train_e2e.py [--full]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
a = ap.parse_args()

if a.full:
    # ~100M params: achieved by training the qwen2.5-3b *architecture family*
    # at reduced width via its smoke config scaled up in train.py flags.
    argv = ["--arch", "qwen2_5_3b", "--smoke", "--steps", "300",
            "--global-batch", "16", "--seq-len", "256",
            "--ckpt-dir", "/tmp/repro_e2e_ck", "--save-every", "50"]
else:
    argv = ["--arch", "qwen2_5_3b", "--smoke", "--steps", "30",
            "--global-batch", "8", "--seq-len", "64",
            "--ckpt-dir", "/tmp/repro_e2e_ck_small", "--save-every", "10"]

out = train.main(argv)
assert min(out["history"][-5:]) <= out["history"][0], "loss should not diverge"
print("train_e2e OK")
