"""Serving example: batched prefill+decode with Leap-paged KV streaming.

Run: PYTHONPATH=src python examples/serve_paged.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve

out = serve.main(["--arch", "qwen2_5_3b", "--smoke", "--batch", "4",
                  "--prompt-len", "32", "--gen", "12", "--paged"])
assert out["paged_prefetch_hit_rate"] > 0.8
print("serve_paged OK")
